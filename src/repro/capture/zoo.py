"""Whole-model zoo: end-to-end decode/train steps -> ``Workload`` entries.

Where :mod:`repro.capture.kernels` captures one Pallas kernel per entry,
this roster captures a *whole jitted step* of each model-zoo config —
``LM.decode_step`` or the :func:`repro.train.step.build_train_step` update
— through :func:`repro.capture.model.capture_model`: every ``dot_general``,
conv, large arithmetic eqn and (if present) ``pallas_call`` in the traced
jaxpr becomes a captured op in one shared address space, concatenated in
real program order with real producer->consumer reuse (see the model
walker's docstring for the region-allocation rules).

Modeling conventions:

- Tracing is abstract (``jax.eval_shape`` params/caches, ShapeDtypeStruct
  tokens): no weights exist, no TPU runs, and the traces are deterministic
  — entries take no rng and are **core-invariant** (data-parallel
  replication: each core runs the same step on its own batch shard, so the
  per-thread trace does not shrink with cores; ``l3_shared`` upstream).
- Decode entries capture one token step against a ``cache_len``-token KV /
  state cache at the serving batch size; train entries capture one full
  update (forward + backward + AdamW) at the training batch size.
- Train traces run to tens of megarefs; they are sampled down to
  ``target_refs`` as one *contiguous steady-state window*
  (:meth:`~repro.capture.model.ModelCapture.walk_window`, centered) —
  cycling a short prefix would misrepresent a step whose phases (forward,
  backward, optimizer) have different locality.  Decode traces land near
  the target naturally and cycle like the captured kernels do.
- AI is the whole-step counted FLOPs (:mod:`repro.capture.flops`) over the
  whole-step refs — the step's true op:byte ratio, not the window's.

Expected classes are pinned from the measured pipeline verdicts (the
roster-stability test recomputes them).  Every zoo step lands in **1b**
— whole steps fuse matmul-heavy ops with their elementwise epilogues, so
per-word arithmetic stays high (AI ~10-40 ops/word), MPKI stays under the
paper's 11.0 threshold, and reuse distances (weight tiles revisited
across k-steps, the residual stream across layers) exceed the Eq.-2
temporal window: the latency-bound, prefetch-friendly profile — the same
branch the standalone flash-attention kernel takes, now shown to hold
for the end-to-end steps it lives in.  That uniformity is itself the
DAMOV-style finding: isolated kernels span 1a/1b/1c, but whole smoke
steps average over their op mix.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.tracegen import TraceSpec, Workload

from .model import ModelCapture, capture_model

__all__ = ["ModelZooEntry", "MODEL_ZOO", "model_workloads"]

# Whole-model entries aim at the same simulated-trace scale as the
# captured kernels (DAMOV's methodology is length-normalized).
_TARGET_REFS = 200_000

# Trace geometry: decode serves a 256-token cache; train sees 128-token
# sequences.  Both are smoke-scale — whole-model capture is about op *mix*
# and reuse structure, not parameter count.
_CACHE_LEN = 256
_TRAIN_SEQ = 128

# Audio (Whisper) steps need encoder frame embeddings next to the tokens.
_AUDIO_FRAMES = 64


@dataclass(frozen=True)
class ModelZooEntry:
    """Declaration of one whole-model suite entry."""

    name: str                   # model.<config>.<mode>.bs<k>
    config: str                 # repro.configs arch name
    mode: str                   # "decode" | "train"
    batch: int
    expected_class: str
    domain: str = "model/dense"  # model/<config family>
    target_refs: int = _TARGET_REFS
    mlp: float = 8.0
    instr_overhead: float = 2.0

    def params(self) -> dict:
        return {
            "config": self.config,
            "mode": self.mode,
            "batch": self.batch,
            "target_refs": self.target_refs,
            "l3": "shared",     # data-parallel replication
            "mlp": self.mlp,
            "geometry": (f"cache{_CACHE_LEN}" if self.mode == "decode"
                         else f"seq{_TRAIN_SEQ}"),
        }


# repro.configs family per arch, mirrored here so importing the zoo
# declarations never needs jax (capture does; see _capture_*).
_FAMILIES = {
    "qwen2.5-14b": "dense", "phi4-mini-3.8b": "dense",
    "nemotron-4-340b": "dense", "granite-20b": "dense",
    "deepseek-moe-16b": "moe", "deepseek-v2-lite-16b": "moe",
    "zamba2-7b": "hybrid", "mamba2-780m": "ssm",
    "whisper-large-v3": "audio", "paligemma-3b": "vlm",
}


def _zoo() -> tuple[ModelZooEntry, ...]:
    decode8 = {
        "qwen2.5-14b": "1b",
        "phi4-mini-3.8b": "1b",
        "nemotron-4-340b": "1b",
        "granite-20b": "1b",
        "deepseek-moe-16b": "1b",
        "deepseek-v2-lite-16b": "1b",
        "zamba2-7b": "1b",
        "mamba2-780m": "1b",
        "whisper-large-v3": "1b",
        "paligemma-3b": "1b",
    }
    train4 = {
        "qwen2.5-14b": "1b",
        "deepseek-moe-16b": "1b",
        "mamba2-780m": "1b",
        "zamba2-7b": "1b",
    }
    decode1 = {
        "qwen2.5-14b": "1b",
        "deepseek-v2-lite-16b": "1b",
    }
    out = []
    for cfg, cls in decode8.items():
        out.append(ModelZooEntry(
            name=f"model.{cfg}.decode.bs8", config=cfg, mode="decode",
            batch=8, expected_class=cls, domain=f"model/{_FAMILIES[cfg]}"))
    for cfg, cls in train4.items():
        out.append(ModelZooEntry(
            name=f"model.{cfg}.train.bs4", config=cfg, mode="train",
            batch=4, expected_class=cls, domain=f"model/{_FAMILIES[cfg]}"))
    for cfg, cls in decode1.items():
        out.append(ModelZooEntry(
            name=f"model.{cfg}.decode.bs1", config=cfg, mode="decode",
            batch=1, expected_class=cls, domain=f"model/{_FAMILIES[cfg]}"))
    return tuple(out)


MODEL_ZOO: tuple[ModelZooEntry, ...] = _zoo()


# One ModelCapture per (config, mode, batch): suite builds, core sweeps
# and the --list AI column all re-request the same step.
_CAPTURES: dict[tuple[str, str, int], ModelCapture] = {}


def _audio_embed(batch: int):
    import jax
    import jax.numpy as jnp

    from repro.configs import get_smoke

    d = get_smoke("whisper-large-v3").d_model
    return jax.ShapeDtypeStruct((batch, _AUDIO_FRAMES, d), jnp.float32)


def _capture_decode(config: str, batch: int) -> ModelCapture:
    import jax
    import jax.numpy as jnp

    from repro.configs import get_smoke
    from repro.models.model import LM

    lm = LM(get_smoke(config))
    params = jax.eval_shape(lambda: lm.init(jax.random.PRNGKey(0)))
    cache = jax.eval_shape(lambda: lm.init_cache(batch, _CACHE_LEN))
    toks = jax.ShapeDtypeStruct((batch, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((batch,), jnp.int32)
    return capture_model(
        lambda p, t, c, po: lm.decode_step(p, t, c, po),
        (params, toks, cache, pos),
        name=f"{config}.decode.bs{batch}")


def _capture_train(config: str, batch: int) -> ModelCapture:
    import jax
    import jax.numpy as jnp

    import repro.train.optimizer as O
    import repro.train.step as T
    from repro.configs import get_smoke
    from repro.models.model import LM

    lm = LM(get_smoke(config))
    opt_cfg = O.AdamWConfig()
    step = T.build_train_step(lm, opt_cfg, microbatches=1)

    def mk_state():
        params = lm.init(jax.random.PRNGKey(0))
        return params, T.init_train_state(lm, params, opt_cfg)

    params, state = jax.eval_shape(mk_state)
    tok = jax.ShapeDtypeStruct((batch, _TRAIN_SEQ), jnp.int32)
    batch_d = {"tokens": tok, "labels": tok}
    if get_smoke(config).family == "audio":
        batch_d["extra_embed"] = _audio_embed(batch)
    return capture_model(
        lambda p, st, b: step(p, st, b), (params, state, batch_d),
        name=f"{config}.train.bs{batch}")


def get_capture(config: str, mode: str, batch: int) -> ModelCapture:
    """The memoized whole-step capture behind one zoo entry."""
    key = (config, mode, batch)
    got = _CAPTURES.get(key)
    if got is None:
        build = _capture_decode if mode == "decode" else _capture_train
        got = _CAPTURES[key] = build(config, batch)
    return got


# Windowed/cycled trace + whole-step accounting, once per entry (the suite
# regenerates traces per core count; these are core-invariant).
_TRACES: dict[str, tuple[np.ndarray, float]] = {}


def _trace_and_ai(spec: ModelZooEntry) -> tuple[np.ndarray, float]:
    got = _TRACES.get(spec.name)
    if got is None:
        mc = get_capture(spec.config, spec.mode, spec.batch)
        addr = mc.walk_window(spec.target_refs).addresses
        if addr.size != spec.target_refs:
            addr = np.resize(addr, spec.target_refs)
        # AI over the WHOLE step's refs, not the window's: per-ref
        # intensity is scale-invariant, so the windowed trace simulated
        # with this AI models the full step's op:byte ratio.
        whole_refs = mc.walk(count_only=True).refs
        ai = mc.flops / whole_refs if whole_refs else 0.0
        got = _TRACES[spec.name] = (addr, ai)
    return got


def _make_gen(spec: ModelZooEntry):
    def gen(cores: int, rng: np.random.Generator) -> TraceSpec:
        del cores, rng  # data-parallel + deterministic abstract trace
        addr, _ = _trace_and_ai(spec)
        return TraceSpec(
            addresses=addr,
            l3_factor=1.0,          # replicated batch shards share the L3
            mlp=spec.mlp,
            dram_rows_irregular=False,
        )
    return gen


def model_workloads(
    specs: tuple[ModelZooEntry, ...] = MODEL_ZOO,
    *,
    only: tuple[str, ...] | None = None,
) -> list[Workload]:
    """Wrap zoo entries as pipeline-ready ``Workload``\\ s (requires jax).

    ``only`` filters by comma-style substrings (any match keeps the
    entry) — the CI roster leg traces two small configs instead of the
    whole zoo.  Filtering never changes per-entry traces or fingerprints,
    so store rows stay recallable across differently-filtered runs.
    """
    picked = [
        s for s in specs
        if only is None or any(sub in s.name for sub in only)
    ]
    out: list[Workload] = []
    for spec in picked:
        _, ai = _trace_and_ai(spec)
        ai = round(ai, 3)
        out.append(Workload(
            name=spec.name,
            family=f"model-{spec.mode}",
            expected_class=spec.expected_class,
            ai_ops_per_access=ai,
            instr_per_access=round(ai + spec.instr_overhead, 3),
            gen=_make_gen(spec),
            core_invariant=True,
        ))
    return out
