"""Data pipeline."""

from .pipeline import SyntheticTokens, make_batch_specs  # noqa: F401
