"""Deterministic synthetic token pipeline, sharded per host.

Design mirrors a production loader:

- **Determinism / restartability**: batch ``i`` depends only on
  ``(seed, i)`` via a counter-based generator (Philox), so restart from a
  checkpointed step reproduces the exact stream — no loader state in the
  checkpoint beyond the step counter.
- **Host sharding**: each process materializes only its
  ``global_batch / process_count`` slice (``jax.process_index()``-based),
  the standard multi-pod input layout; ``jax.make_array_from_process_local_data``
  assembles the global array.
- **Prefetch**: a background thread keeps ``prefetch`` batches ready.

The "dataset" is a deterministic token stream with a power-law unigram
distribution plus Markov bigram structure so the LM loss has signal —
enough to exercise the training loop end-to-end (the paper's technique is
orthogonal to data content).
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass

import jax
import numpy as np

__all__ = ["SyntheticTokens", "make_batch_specs"]


def make_batch_specs(cfg, shape, *, img_tokens: int = 0,
                     enc_ctx: int = 0) -> dict:
    """ShapeDtypeStructs for a training batch (dry-run input stand-ins)."""
    import jax.numpy as jnp

    b, s = shape.global_batch, shape.seq_len
    specs = {
        "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
        "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
    }
    if img_tokens:
        specs["extra_embed"] = jax.ShapeDtypeStruct(
            (b, img_tokens, cfg.d_model), jnp.dtype(cfg.dtype))
    if enc_ctx:
        specs["extra_embed"] = jax.ShapeDtypeStruct(
            (b, enc_ctx, cfg.d_model), jnp.dtype(cfg.dtype))
    return specs


@dataclass
class SyntheticTokens:
    vocab: int
    global_batch: int
    seq_len: int
    seed: int = 0
    prefetch: int = 2
    extra_embed_len: int = 0     # VLM patch / audio frame stand-ins
    d_model: int = 0
    zipf_a: float = 1.2

    def __post_init__(self):
        self._procs = jax.process_count()
        self._pid = jax.process_index()
        assert self.global_batch % self._procs == 0
        self._local_batch = self.global_batch // self._procs
        self._q: queue.Queue = queue.Queue(maxsize=self.prefetch)
        self._thread: threading.Thread | None = None
        self._stop = threading.Event()
        self._next_step = 0

    # -- deterministic batch synthesis -----------------------------------
    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        rng = np.random.Generator(np.random.Philox(key=self.seed, counter=step))
        b, s = self._local_batch, self.seq_len
        # power-law unigrams + shift-structure so bigrams are learnable
        base = rng.zipf(self.zipf_a, size=(b, s + 1)).astype(np.int64)
        tokens = (base + np.arange(s + 1)[None, :] * 7) % self.vocab
        out = {
            "tokens": tokens[:, :-1].astype(np.int32),
            "labels": tokens[:, 1:].astype(np.int32),
        }
        if self.extra_embed_len:
            out["extra_embed"] = rng.standard_normal(
                (b, self.extra_embed_len, self.d_model), dtype=np.float32)
        return out

    # -- prefetching iterator --------------------------------------------
    def _worker(self):
        step = self._next_step
        while not self._stop.is_set():
            try:
                self._q.put((step, self.batch_at(step)), timeout=0.2)
                step += 1
            except queue.Full:
                continue

    def start(self, step: int = 0) -> "SyntheticTokens":
        self._next_step = step
        self._stop.clear()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()
        return self

    def stop(self):
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=2.0)
            self._thread = None
        while not self._q.empty():
            self._q.get_nowait()

    def __iter__(self):
        while True:
            if self._thread is None:
                yield self.batch_at(self._next_step)
                self._next_step += 1
            else:
                _, batch = self._q.get()
                yield batch

    def global_arrays(self, batch: dict, mesh, batch_spec) -> dict:
        """Assemble process-local slices into global jax.Arrays."""
        from jax.sharding import NamedSharding

        def one(x):
            sharding = NamedSharding(mesh, batch_spec)
            return jax.make_array_from_process_local_data(sharding, x)

        return {k: one(v) for k, v in batch.items()}
