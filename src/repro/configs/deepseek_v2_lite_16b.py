"""deepseek-v2-lite-16b [arXiv:2405.04434; hf]

27L d_model=2048 16H d_ff=1408 vocab=102400, MLA kv_lora=512,
MoE: 2 shared + 64 routed, top-6.  (The assignment bracket mentions "160
routed" — that is the full V2; V2-Lite has 64 routed experts, matching the
"MoE 64e top-6" field.  We follow the 64e field; see DESIGN.md.)
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=102_400,
    mlp_kind="swiglu",
    n_routed_experts=64,
    n_shared_experts=2,
    top_k=6,
    d_ff_expert=1408,
    kv_lora_rank=512,
    rope_head_dim=64,
    nope_head_dim=128,
    v_head_dim=128,
)

SMOKE = CONFIG.replace(
    name="deepseek-v2-lite-16b-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    d_ff_expert=128,
    n_routed_experts=8,
    top_k=2,
    vocab=512,
    kv_lora_rank=32,
    rope_head_dim=8,
    nope_head_dim=16,
    v_head_dim=16,
    attn_chunk=64,
)
