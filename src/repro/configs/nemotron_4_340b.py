"""nemotron-4-340b [arXiv:2402.16819]

96L d_model=18432 96H (GQA kv=8) d_ff=73728 vocab=256000 —
GQA + squared-ReLU MLP (no gate).
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b",
    family="dense",
    n_layers=96,
    d_model=18_432,
    n_heads=96,
    n_kv_heads=8,
    d_ff=73_728,
    vocab=256_000,
    mlp_kind="relu2",
)

SMOKE = CONFIG.replace(
    name="nemotron-4-340b-smoke",
    n_layers=2,
    d_model=96,
    n_heads=6,
    n_kv_heads=2,
    d_ff=384,
    vocab=512,
    attn_chunk=64,
)
