"""whisper-large-v3 [arXiv:2212.04356]

32L (enc) + 32L (dec) d_model=1280 20H d_ff=5120 vocab=51866 — enc-dec;
the conv/mel frontend is a STUB: input_specs() supplies 1500 precomputed
frame embeddings per example.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    n_layers=32,
    n_enc_layers=32,
    enc_ctx=1500,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab=51_866,
    mlp_kind="gelu",
)

SMOKE = CONFIG.replace(
    name="whisper-large-v3-smoke",
    n_layers=2,
    n_enc_layers=2,
    enc_ctx=32,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab=512,
    attn_chunk=64,
)
