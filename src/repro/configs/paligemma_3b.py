"""paligemma-3b [arXiv:2407.07726; hf]

18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=257216 — Gemma backbone;
the SigLIP vision tower is a STUB: input_specs() supplies 256 precomputed
patch embeddings per image.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    d_ff=16_384,
    vocab=257_216,
    mlp_kind="gelu",
    n_img_tokens=256,
    head_dim=256,
)

SMOKE = CONFIG.replace(
    name="paligemma-3b-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=1,
    d_ff=128,
    vocab=512,
    n_img_tokens=8,
    head_dim=16,
    attn_chunk=64,
)
