"""granite-20b [arXiv:2405.04324]

52L d_model=6144 48H (MQA kv=1) d_ff=24576 vocab=49152 — llama-arch, code.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b",
    family="dense",
    n_layers=52,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_ff=24_576,
    vocab=49_152,
    mlp_kind="swiglu",
)

SMOKE = CONFIG.replace(
    name="granite-20b-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=1,
    d_ff=192,
    vocab=512,
    attn_chunk=64,
)
