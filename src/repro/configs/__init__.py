"""Architecture registry: exact public configs + reduced smoke variants.

``get(name)`` returns the full assigned config; ``get_smoke(name)`` returns
a same-family reduced config that runs a forward/train step on CPU in
seconds (small layers/width, few experts, tiny vocab).
"""

from __future__ import annotations

from ..models.config import ModelConfig, SHAPES, ShapeSpec  # noqa: F401

from . import (
    deepseek_moe_16b,
    deepseek_v2_lite_16b,
    qwen2_5_14b,
    phi4_mini_3_8b,
    nemotron_4_340b,
    granite_20b,
    zamba2_7b,
    mamba2_780m,
    whisper_large_v3,
    paligemma_3b,
)

_MODULES = {
    "deepseek-moe-16b": deepseek_moe_16b,
    "deepseek-v2-lite-16b": deepseek_v2_lite_16b,
    "qwen2.5-14b": qwen2_5_14b,
    "phi4-mini-3.8b": phi4_mini_3_8b,
    "nemotron-4-340b": nemotron_4_340b,
    "granite-20b": granite_20b,
    "zamba2-7b": zamba2_7b,
    "mamba2-780m": mamba2_780m,
    "whisper-large-v3": whisper_large_v3,
    "paligemma-3b": paligemma_3b,
}

ARCHS = tuple(_MODULES)


def get(name: str) -> ModelConfig:
    return _MODULES[name].CONFIG


def get_smoke(name: str) -> ModelConfig:
    return _MODULES[name].SMOKE


def shapes_for(name: str) -> tuple[str, ...]:
    """Applicable shape cells for an architecture (assignment rules):

    - ``long_500k`` runs only for sub-quadratic archs (SSM / hybrid);
      pure full-attention archs skip it (noted in DESIGN.md).
    - every arch runs train_4k / prefill_32k / decode_32k (decoder exists
      for all ten: whisper/paligemma decode exercises the backbone).
    """
    cfg = get(name)
    base = ("train_4k", "prefill_32k", "decode_32k")
    if cfg.family in ("ssm", "hybrid"):
        return base + ("long_500k",)
    return base
