"""qwen2.5-14b [hf:Qwen/Qwen2.5-*]

48L d_model=5120 40H (GQA kv=8) d_ff=13824 vocab=152064 — GQA with QKV bias.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b",
    family="dense",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=13_824,
    vocab=152_064,
    qkv_bias=True,
    mlp_kind="swiglu",
    rope_theta=1_000_000.0,
)

SMOKE = CONFIG.replace(
    name="qwen2.5-14b-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=160,
    vocab=512,
    attn_chunk=64,
)
