"""zamba2-7b [arXiv:2411.15242]

81L d_model=3584 32H (kv=32) d_ff=14336 vocab=32000, ssm_state=64 —
Mamba2 blocks + ONE shared attention+MLP block applied every 6th position
(weight reuse across applications), our layout for the Zamba2 shared-block
architecture: 13 x [5 SSM + shared attn] + 3 trailing SSM = 81 blocks.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14_336,
    vocab=32_000,
    mlp_kind="swiglu",
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    attn_every=6,
)

SMOKE = CONFIG.replace(
    name="zamba2-7b-smoke",
    n_layers=7,          # 1 group of 5 SSM + shared attn + 1 trailing SSM
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab=512,
    ssm_state=16,
    ssm_head_dim=16,
    ssm_chunk=32,
    attn_every=6,
    attn_chunk=64,
)
