"""deepseek-moe-16b [arXiv:2401.06066; hf]

28L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=102400,
MoE: 2 shared + 64 routed experts, top-6, fine-grained (d_ff_expert=1408).
(The real model keeps layer 0 dense; we use a uniform MoE stack to keep the
layer scan homogeneous — noted in DESIGN.md §Assumptions.)
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=102_400,
    mlp_kind="swiglu",
    n_routed_experts=64,
    n_shared_experts=2,
    top_k=6,
    d_ff_expert=1408,
)

SMOKE = CONFIG.replace(
    name="deepseek-moe-16b-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    d_ff_expert=128,
    n_routed_experts=8,
    top_k=2,
    vocab=512,
    attn_chunk=64,
)
