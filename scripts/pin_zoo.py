"""Regenerate the model zoo's pinned (AI, class) table.

Runs every swept zoo entry through the full capture -> locality ->
core-sweep -> classify pipeline (computing AI from live captures, i.e.
ignoring any existing pins) and prints the ``_PINS`` literal for
``src/repro/capture/zoo.py`` plus the measured transition boundaries.

Usage::

    PYTHONPATH=src python scripts/pin_zoo.py [--only SUB[,SUB]]
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from repro.capture import zoo
from repro.core import classify
from repro.core.tracegen import Workload
from repro.study.engine import SimEngine
from repro.study.study import Study


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    only = tuple(args.only.split(",")) if args.only else None

    specs = [s for s in zoo.MODEL_ZOO
             if only is None or any(sub in s.name for sub in only)]
    # Strip pins: recompute AI from live captures.
    from dataclasses import replace
    specs = [replace(s, ai=None) for s in specs]
    workloads = zoo.model_workloads(tuple(specs))
    study = Study(suite=workloads)

    print(f"# {len(specs)} entries", file=sys.stderr)
    t_all = time.time()
    lines = []
    for spec, w in zip(specs, workloads):
        t0 = time.time()
        m = study.metrics(w)
        cls = classify.classify(m)
        lines.append(f'    "{spec.name}": ({w.ai_ops_per_access}, "{cls}"),')
        print(f"{spec.name:48s} ai={w.ai_ops_per_access:8.3f} -> {cls} "
              f"(t={m.temporal:.3f} mpki={m.mpki:.1f} "
              f"lfmr={m.lfmr_mean:.3f} slope={m.lfmr_slope:.3f}) "
              f"[{time.time()-t0:.1f}s]", file=sys.stderr)
    print(f"# total {time.time()-t_all:.0f}s", file=sys.stderr)
    print("_PINS: dict[str, tuple[float, str]] = {")
    print("\n".join(lines))
    print("}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
