"""Quickstart: the DAMOV methodology end-to-end on a new 'application'.

Characterizes a workload the classifier has never seen (a blocked
matrix-transpose access pattern) through the unified ``repro.study`` API:
one :class:`~repro.study.Study` holds the workload, its memoized engine
runs each simulation cell once, and metrics / classification / scalability
are cached queries over it.  Then shows the TPU-side analogue: the same
Step-3 question answered by the ``hlo`` substrate for an LM training step.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro import configs
from repro.core import analytic, hlo_analysis, tracegen
from repro.models.config import SHAPES
from repro.study import Study


def make_transpose_workload(n: int = 1024) -> tracegen.Workload:
    """Naive out-of-place transpose of an n x n f64 matrix: rows stream,
    columns stride — the DAMOV 1a-style pattern every textbook uses."""

    def gen(cores, rng):
        rows = np.arange(n * n // cores, dtype=np.int64)           # A[i][j]
        cols = (rows % n) * n + rows // n                          # B[j][i]
        addr = np.empty(2 * rows.size, dtype=np.int64)
        addr[0::2] = rows
        addr[1::2] = 2 ** 27 + cols
        return tracegen.TraceSpec(addr[:120_000], l3_factor=1.0 / cores,
                                  mlp=6.0, dram_rows_irregular=False)

    return tracegen.Workload(
        name="Transpose", family="stream", expected_class="1a",
        ai_ops_per_access=0.5, instr_per_access=2.5, gen=gen)


def main():
    print("=== DAMOV Steps 1-3 on a new workload (repro.study API) ===")
    w = make_transpose_workload()
    study = Study(suite=[w])

    spatial, temporal = study.locality(w)
    m = study.metrics(w)
    cls = study.classify(w)
    print(f"workload={w.name}")
    print(f"  Step 2 (arch-independent): temporal={temporal:.2f} "
          f"spatial={spatial:.2f}")
    print(f"  Step 3 (arch-dependent):   AI={m.ai:.1f} MPKI={m.mpki:.1f} "
          f"LFMR={[round(x, 2) for x in m.lfmr_by_cores]}")
    print(f"  -> bottleneck class {cls} "
          f"({'DRAM bandwidth-bound' if cls == '1a' else cls})")

    r = study.scalability(w)
    sp = r.speedup_ndp_vs_host()
    print(f"  NDP speedup across 1..256 cores: "
          f"{[round(s, 2) for s in sp]}")
    verdict = "NDP-friendly" if np.mean(sp) > 1.1 else "cache-friendly"
    print(f"  verdict: {verdict}")
    s = study.stats
    print(f"  engine: {study.engine.cells} cells simulated once, "
          f"{s.sim_hits} recalled from cache\n")

    print("=== TPU analogue: classify an LM training step ===")
    cfg = configs.get("deepseek-moe-16b")
    shape = SHAPES["train_4k"]
    cost = analytic.cell_cost(cfg, shape, kind="train", microbatches=2,
                              data_shards=16, model_shards=16)
    rt = hlo_analysis.RooflineTerms(
        name="deepseek-moe train_4k", chips=256,
        hlo_flops=cost.flops, hlo_bytes=cost.hbm_bytes,
        collective_bytes=cost.collective_bytes,
        model_flops=cfg.model_flops(shape.global_batch * shape.seq_len))
    s = rt.summary()
    print(f"  t_compute={s['t_compute_s']:.3e}s  "
          f"t_memory={s['t_memory_s']:.3e}s  "
          f"t_collective={s['t_collective_s']:.3e}s")
    print(f"  -> class={s['class']}  mfu_bound={s['mfu_bound']:.3f}")
    print("  (the hlo substrate: python -m repro.study --substrate hlo)")


if __name__ == "__main__":
    main()
