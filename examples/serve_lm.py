"""Serving example: continuous batching over a slot-pool engine.

Submits a burst of variable-length prompts against a 4-slot engine (more
requests than slots — slots recycle as requests finish), streams tokens as
they are emitted, and verifies greedy consistency against full forward.

Run:  PYTHONPATH=src python examples/serve_lm.py [--arch mamba2-780m]
"""

import argparse

import jax
import numpy as np

from repro import configs
from repro.models import LM
from repro.serve import Engine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-780m", choices=configs.ARCHS)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch)
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    engine = Engine(lm, params, max_batch=4, max_len=64,
                    prompt_buckets=(8, 16))

    rng = np.random.default_rng(0)
    reqs = [
        Request(rid=i, prompt=rng.integers(1, cfg.vocab,
                                           size=int(rng.integers(3, 14))),
                max_new_tokens=args.max_new)
        for i in range(args.requests)
    ]
    for r in reqs:
        engine.submit(r)

    step = 0
    while engine.queue or engine.active:
        emitted = engine.step()
        step += 1
        if emitted:
            print(f"step {step:3d}: " + "  ".join(
                f"req{rid}->{tok}" for rid, tok in emitted))
    print("\nfinal outputs:")
    for r in reqs:
        print(f"  req{r.rid} ({len(r.prompt)}-token prompt): {r.out_tokens}")
    assert all(len(r.out_tokens) >= 1 for r in reqs)
    print(f"served {len(reqs)} requests through 4 slots in {step} steps.")


if __name__ == "__main__":
    main()
