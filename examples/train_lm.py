"""End-to-end training driver: train a (reduced) assigned architecture for a
few hundred steps on CPU with checkpointing + resume.

The full-size path is identical — swap get_smoke() for get() and run on a
TPU slice with the production mesh (see src/repro/launch/train.py, which
this example wraps).

Run:  PYTHONPATH=src python examples/train_lm.py [--arch qwen2.5-14b]
      PYTHONPATH=src python examples/train_lm.py --kill-and-resume
"""

import argparse
import shutil

from repro import configs
from repro.launch.train import train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-14b", choices=configs.ARCHS)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--kill-and-resume", action="store_true",
                    help="demonstrate fault tolerance: run half, 'crash', "
                         "resume from the checkpoint")
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch)
    ckpt = "/tmp/repro_example_ckpt"
    shutil.rmtree(ckpt, ignore_errors=True)

    if args.kill_and_resume:
        half = args.steps // 2
        print(f"--- phase 1: steps 0..{half} (then simulated failure) ---")
        train_loop(cfg, steps=half, global_batch=8, seq_len=64,
                   ckpt_dir=ckpt, save_every=20, log_every=20)
        print("--- node 'failed'; restarting and resuming ---")
        _, _, losses = train_loop(cfg, steps=args.steps, global_batch=8,
                                  seq_len=64, ckpt_dir=ckpt, save_every=50,
                                  resume=True, log_every=20)
    else:
        _, _, losses = train_loop(cfg, steps=args.steps, global_batch=8,
                                  seq_len=64, ckpt_dir=ckpt, save_every=100,
                                  log_every=20)
    print(f"first-10 mean loss {sum(losses[:10]) / 10:.4f} -> "
          f"last-10 mean loss {sum(losses[-10:]) / 10:.4f}")
    assert sum(losses[-10:]) < sum(losses[:10]), "loss should decrease"
    print("training signal confirmed (loss decreased).")


if __name__ == "__main__":
    main()
