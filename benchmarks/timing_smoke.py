"""CI timing smoke: the vectorized backend must stay hardware-speed.

Times one full-length host-config simulation cell per workload family on
the vectorized backend — at the pipeline's real default trace length,
``tracegen.DEFAULT_REFS`` (250k refs), so the gate times what the figure
and suite sweeps actually run — and fails if any cell exceeds the budget
(default 2.0 s; the slowest family's cold cell measures ~0.2 s, so the
gate catches algorithmic regressions, not CI jitter).  With ``--compare``
it also times the reference loop and reports the speedup per family.

Each timed call passes a *fresh* address array, which defeats the
identity-keyed per-trace memo in ``cachesim_vec`` — the gate times a cold
cell, not a memo recall.

Usage::

    PYTHONPATH=src python -m benchmarks.timing_smoke [--budget 2.0] [--compare]
"""

from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from repro.core import cachesim, cachesim_vec, tracegen

REFS = tracegen.DEFAULT_REFS


def _time(fn, repeats: int) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--budget", type=float, default=2.0,
                    help=f"max seconds per vectorized {REFS}-ref cell")
    ap.add_argument("--compare", action="store_true",
                    help="also time the reference loop and print speedups")
    ap.add_argument("--min-speedup", type=float, default=0.0,
                    help="with --compare: fail if the aggregate "
                         "reference/vectorized time ratio over all families "
                         "drops below this (guards against silently losing "
                         "vectorization; the per-cell budget alone would "
                         "pass at reference-loop speed)")
    ap.add_argument("--min-best-speedup", type=float, default=0.0,
                    help="with --compare: fail if no family reaches this "
                         "speedup (the acceptance criterion: a full-length "
                         "host cell >= 10x; streaming families clear it "
                         "with wide margin, so this is noise-robust)")
    args = ap.parse_args(argv)

    byfam: dict[str, tracegen.Workload] = {}
    for w in tracegen.make_suite(refs=REFS):
        byfam.setdefault(w.family, w)

    failures = []
    total_vec = total_ref = 0.0
    best_speedup = 0.0
    for family, w in sorted(byfam.items()):
        spec = w.trace(1)
        cfg = cachesim.host_config(1)
        cachesim_vec.simulate(spec.addresses, cfg,
                              l3_factor=spec.l3_factor)  # warm
        t_vec = _time(
            # fresh array each call: defeat the identity-keyed per-trace
            # memo so the gate times a cold cell
            lambda: cachesim_vec.simulate(np.array(spec.addresses), cfg,
                                          l3_factor=spec.l3_factor),
            repeats=3,
        )
        total_vec += t_vec
        line = f"{family:10s} vec={t_vec * 1e3:7.1f}ms"
        if args.compare:
            t_ref = _time(
                lambda: cachesim.simulate(spec.addresses, cfg,
                                          backend="reference",
                                          l3_factor=spec.l3_factor),
                repeats=2,
            )
            total_ref += t_ref
            best_speedup = max(best_speedup, t_ref / t_vec)
            line += f"  ref={t_ref * 1e3:7.1f}ms  speedup={t_ref / t_vec:5.1f}x"
        print(line)
        if t_vec > args.budget:
            failures.append((family, t_vec))

    for family, t in failures:
        print(f"FAIL: {family} vectorized {REFS}-ref cell took {t:.2f}s "
              f"(> {args.budget:.2f}s budget)", file=sys.stderr)
    if args.compare:
        aggregate = total_ref / total_vec
        print(f"aggregate speedup over {len(byfam)} families: {aggregate:.1f}x"
              f" (best family: {best_speedup:.1f}x)")
        if args.min_speedup and aggregate < args.min_speedup:
            print(f"FAIL: aggregate speedup {aggregate:.1f}x < "
                  f"{args.min_speedup:.1f}x floor", file=sys.stderr)
            return 1
        if args.min_best_speedup and best_speedup < args.min_best_speedup:
            print(f"FAIL: best-family speedup {best_speedup:.1f}x < "
                  f"{args.min_best_speedup:.1f}x floor", file=sys.stderr)
            return 1
    if failures:
        return 1
    print(f"ok: all families within the {args.budget:.2f}s budget")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
