"""Render §Dry-run / §Roofline markdown tables from results/dryrun."""

from __future__ import annotations

import glob
import json
import os
import sys


def load(d):
    rows = []
    for f in sorted(glob.glob(os.path.join(d, "*.json"))):
        if "_skips" in f:
            continue
        rows.append(json.load(open(f)))
    return rows


def main(d="results/dryrun"):
    rows = load(d)
    print("| arch | shape | mesh | compile_s | arg GB/chip | temp GB/chip |"
          " t_comp | t_mem | t_coll | class | MFU-bound | useful |")
    print("|---|---|---|---|---|---|---|---|---|---|---|---|")
    for r in sorted(rows, key=lambda x: (x["arch"], x["shape"], x["mesh"])):
        if r["status"] != "ok":
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | ERROR "
                  f"{r.get('error','')[:60]} |")
            continue
        m = r["memory"]
        print(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compile_s']} "
            f"| {m.get('argument_size_in_bytes', 0)/1e9:.1f} "
            f"| {m.get('temp_size_in_bytes', 0)/1e9:.1f} "
            f"| {r['t_compute_s']:.2e} | {r['t_memory_s']:.2e} "
            f"| {r['t_collective_s']:.2e} | {r['class']} "
            f"| {r['mfu_bound']:.3f} | {r['useful_compute_ratio']:.2f} |")


if __name__ == "__main__":
    main(*sys.argv[1:])
