"""Benchmark driver: one section per DAMOV table/figure + the TPU tables.

Usage::

    PYTHONPATH=src python -m benchmarks.run [--fast] [--only SECTION]

All figure sections are queries over ONE shared :class:`repro.study.Study`:
the memoized engine simulates each (workload, cores, config) cell exactly
once and every section reuses it, so the full run is one simulation pass.

Sections map 1:1 to paper artifacts:

- fig1   — roofline + MPKI vs NDP speedup (Fig. 1)
- fig3   — locality-based clustering (Fig. 3)
- fig4   — LFMR/MPKI per function (Fig. 4)
- fig5   — scalability curves, 3 systems (Figs. 5, 16)
- fig7   — energy breakdowns (Figs. 7-17)
- fig18  — per-class NDP-speedup summary + §3.5 validation accuracy
- table3 — the registered benchmark-suite roster (repro.suite): synthetic
           family expansions + captured Pallas-kernel traces in one
           classification table
- case1..case4 — §5 case studies
- roofline — §Roofline TPU table (from results/dryrun artifacts)
- kernels  — Pallas kernel microbench + v5e roofline bounds
"""

from __future__ import annotations

import argparse
import sys
import time

from repro.study import Study, StudyResult
from repro.suite import ResultStore

from . import kernel_bench, paper_figures, roofline_table


def emit(section: str, result) -> list[tuple]:
    if isinstance(result, StudyResult):
        rows, header = result.to_rows(), result.columns
    else:
        rows, header = result
    print(f"\n## {section}")
    print(",".join(str(h) for h in header))
    for r in rows:
        print(",".join(str(x) for x in r))
    sys.stdout.flush()
    return rows


def main() -> None:
    from repro.core.cachesim import BACKENDS
    from repro.core.tracegen import DEFAULT_REFS

    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="reduced trace length (CI)")
    ap.add_argument("--only", default=None)
    ap.add_argument("--backend", choices=BACKENDS, default=None,
                    help="cache-simulation implementation; default: "
                         "$REPRO_SIM_BACKEND or 'vectorized'")
    args = ap.parse_args()

    refs = 20_000 if args.fast else DEFAULT_REFS
    study = Study(refs=refs, backend=args.backend)

    sections = {
        "fig1": lambda: paper_figures.fig1_roofline_mpki(study),
        "fig3": lambda: paper_figures.fig3_locality_clustering(study),
        "fig4": lambda: paper_figures.fig4_lfmr_mpki(study),
        "fig5": lambda: paper_figures.fig5_scalability(study),
        "fig5_nuca": lambda: paper_figures.fig5_scalability(study, nuca=True),
        "fig7": lambda: paper_figures.fig7_energy(study),
        "fig18": lambda: paper_figures.fig18_summary_and_validation(study),
        # table3 shares the suite CLI's content-addressed result store, so
        # repeat benchmark runs recall the roster instead of re-simulating
        "table3": lambda: paper_figures.table3_suite_roster(
            refs=refs, store=ResultStore(), backend=args.backend),
        "case1": lambda: paper_figures.case1_noc(study),
        "case2": lambda: paper_figures.case2_accelerators(study),
        "case3": lambda: paper_figures.case3_core_models(study),
        "case4": lambda: paper_figures.case4_offload(study),
        "roofline": roofline_table.rows,
        "kernels_stream": kernel_bench.stream_rows,
        "kernels_attention": kernel_bench.attention_rows,
    }
    if args.fast:
        sections.pop("fig18")  # the 70-workload held-out sweep is slow

    for name, fn in sections.items():
        if args.only and args.only != name:
            continue
        t0 = time.time()
        result = fn()
        rows = emit(name, result)
        print(f"# {name}: {len(rows)} rows in {time.time() - t0:.1f}s")

    s = study.stats
    print(f"# engine: {study.engine.cells} cells, "
          f"{s.sim_runs} simulated, {s.sim_hits} cache hits "
          f"({s.sim_hit_rate:.0%} hit rate)")


if __name__ == "__main__":
    main()
