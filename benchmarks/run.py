"""Benchmark driver: one section per DAMOV table/figure + the TPU tables.

Usage::

    PYTHONPATH=src python -m benchmarks.run [--fast] [--only SECTION]

All figure sections are queries over ONE shared :class:`repro.study.Study`:
the memoized engine simulates each (workload, cores, config) cell exactly
once — submitting every sweep through the batched single-pass backend —
and every section reuses it, so the full run is one simulation pass.

Sections map 1:1 to paper artifacts:

- fig1   — roofline + MPKI vs NDP speedup (Fig. 1)
- fig3   — locality-based clustering (Fig. 3)
- fig4   — LFMR/MPKI per function (Fig. 4)
- fig5   — scalability curves, 3 systems (Figs. 5, 16)
- fig7   — energy breakdowns (Figs. 7-17)
- fig18  — per-class NDP-speedup summary + §3.5 validation accuracy
- table3 — the registered benchmark-suite roster (repro.suite): synthetic
           family expansions + captured Pallas-kernel traces in one
           classification table
- suite  — the suite subsystem's per-class histogram over the same
           runner/roster (the CI smoke for the repro.suite path; shares
           table3's runner, engine and result store)
- serving / serving_warm — the repro.serving traffic-scenario roster with
           phase-timeline columns: ``serving`` composes + classifies the
           16 scenarios cold against a fresh throwaway store, then
           ``serving_warm`` re-rosters against that store, timing the
           pure content-addressed recall path
- models — the whole-model roster (repro.capture.zoo): traces + classifies
           the CI-pair subset of the 176-entry axis sweep (one dense + one
           SSM config across decode/prefill/eval/train x batch x geometry)
           cold against its own throwaway store, timing jaxpr walk + eqn
           lowering + windowed trace walks end to end (skipped when jax
           is unavailable)
- models_sweep — the streamed whole-step data path: the zoo's bs64 decode
           megaref walk fed op-by-op (ModelCapture.walk_stream) into
           cachesim_stream.simulate_chunked, never materializing a
           concatenated trace (CI gates capture.model.concat==0 over
           this section's obs trace)
- megaref — the chunk-streaming simulator over one long bounded-footprint
           trace (2M refs fast / 10M full), always cold: times
           ``cachesim_stream.simulate_chunked`` end to end
- case1..case4 — §5 case studies
- roofline — §Roofline TPU table (from results/dryrun artifacts)
- kernels  — Pallas kernel microbench + v5e roofline bounds

Every run also writes a machine-readable perf record (default
``BENCH.json``): per-section wall-clock + row counts, the resolved
backend and batch mode, and engine cell statistics.  The file is
merge-updated — keys this driver does not own (e.g. a committed baseline
comparison block) are preserved — so the perf trajectory is trackable
across PRs.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

from repro.study import Study, StudyResult
from repro.suite import ResultStore

from . import kernel_bench, paper_figures, roofline_table


def emit(section: str, result) -> list[tuple]:
    if isinstance(result, StudyResult):
        rows, header = result.to_rows(), result.columns
    else:
        rows, header = result
    print(f"\n## {section}")
    print(",".join(str(h) for h in header))
    for r in rows:
        print(",".join(str(x) for x in r))
    sys.stdout.flush()
    return rows


def write_bench_json(path: str, config_key: str, payload: dict,
                     *, partial: bool) -> None:
    """Merge-update the perf record.

    Section timings are only comparable under one configuration, so runs
    are bucketed under ``runs[config_key]`` (fast mode + refs + backend):
    a ``partial`` (``--only``) run refreshes just its own entries inside
    its own bucket, a full run replaces its bucket's sections wholesale
    (so renamed/removed sections cannot linger), and runs under a
    *different* configuration — e.g. the CI smoke executed locally — can
    never clobber another bucket.  Keys this driver does not own (e.g. a
    committed baseline-comparison block) are preserved.
    """
    existing: dict = {}
    try:
        with open(path) as f:
            existing = json.load(f)
    except (FileNotFoundError, json.JSONDecodeError):
        pass
    runs = existing.setdefault("runs", {})
    bucket = runs.setdefault(config_key, {})
    sections = bucket.get("sections", {}) if partial else {}
    sections.update(payload.pop("sections"))
    bucket.update(payload)
    bucket["sections"] = sections
    with open(path, "w") as f:
        json.dump(existing, f, indent=2, sort_keys=True)
        f.write("\n")


def calibration_seconds() -> float:
    """Wall-clock of a fixed NumPy workload, recorded into the perf
    record's meta so ``benchmarks.perf_gate`` can normalize section
    timings across machines of different speed (the committed baseline
    encodes the recording machine's clock)."""
    import numpy as np

    rng = np.random.default_rng(0)
    a = rng.standard_normal(2**21)
    t0 = time.time()
    for _ in range(3):
        np.sort(a)
        np.argsort(a[: 2**19])
    return time.time() - t0


def main() -> None:
    from repro.core.cachesim import BACKENDS, default_backend
    from repro.core.tracegen import DEFAULT_REFS

    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="reduced trace length (CI)")
    ap.add_argument("--only", default=None)
    ap.add_argument("--backend", choices=BACKENDS, default=None,
                    help="cache-simulation implementation; default: "
                         "$REPRO_SIM_BACKEND or 'vectorized'")
    ap.add_argument("--bench-json", default="BENCH.json", metavar="PATH",
                    help="perf-record output path ('' disables)")
    args = ap.parse_args()

    refs = 20_000 if args.fast else DEFAULT_REFS
    study = Study(refs=refs, backend=args.backend)

    # table3 and suite share one runner (engine + content-addressed result
    # store), so repeat benchmark runs recall the roster instead of
    # re-simulating and the suite section is free once table3 ran.
    runner_box: list = []

    def suite_runner():
        if not runner_box:
            from repro.suite import SuiteRunner, default_registry
            runner_box.append(SuiteRunner(
                default_registry(refs=refs), store=ResultStore(),
                backend=args.backend))
        return runner_box[0]

    def suite_histogram():
        runner = suite_runner()
        res = runner.histogram()
        res.name = "suite"
        return res

    # serving roster: cold composition+classification vs pure store recall.
    # The cold section owns a throwaway store so repeat benchmark runs stay
    # cold (committing it to the default store would turn "cold" into a
    # recall timing on the second run).
    serving_store_box: list = []

    def _serving_store() -> ResultStore:
        if not serving_store_box:
            import atexit
            import shutil
            import tempfile

            tmp = tempfile.mkdtemp(prefix="bench-serving-store-")
            atexit.register(shutil.rmtree, tmp, ignore_errors=True)
            serving_store_box.append(ResultStore(tmp))
        return serving_store_box[0]

    def serving_roster(section: str):
        from repro.suite import SuiteRunner, serving_registry

        runner = SuiteRunner(serving_registry(refs=refs),
                             store=_serving_store(), backend=args.backend,
                             sections=("serving",))
        res = runner.roster()
        res.name = section
        return res

    # whole-model roster: cold jaxpr walk + windowed trace + classify.
    # The zoo is now a 176-entry axis sweep, so this section times the
    # CI-pair subset (one dense + one SSM config across every mode /
    # batch / geometry — 46 entries); the full sweep is a local run.
    # Same throwaway-store rationale as serving; needs jax to trace
    # (gated, not stubbed — there is no jax-free fallback).
    def models_roster():
        from repro.suite import SuiteRunner, models_registry
        from repro.study.result import StudyResult

        try:
            import jax  # noqa: F401
        except ImportError:
            return StudyResult(name="models", columns=("name", "note"),
                               rows=[("models", "skipped: no jax")])
        runner = SuiteRunner(
            models_registry(refs=refs,
                            only=("qwen2.5-14b", "mamba2-780m")),
            store=_serving_store(), backend=args.backend,
            sections=("models",))
        res = runner.roster()
        res.name = "models"
        return res

    # models_sweep: the streamed whole-step data path — the zoo's biggest
    # decode entry (bs64, a megaref walk) fed op-by-op from
    # ModelCapture.walk_stream into cachesim_stream.simulate_chunked.  No
    # concatenated trace array is ever materialized (the obs counter gate
    # in CI asserts capture.model.concat==0 over this section), so peak
    # trace memory is the largest single op.  Always cold, like megaref.
    def models_sweep_rows():
        from repro.study.result import StudyResult

        try:
            import jax  # noqa: F401
        except ImportError:
            return StudyResult(name="models_sweep",
                               columns=("name", "note"),
                               rows=[("models_sweep", "skipped: no jax")])
        from repro.capture.zoo import capture_for
        from repro.core import cachesim
        from repro.core.cachesim_stream import DEFAULT_CHUNK, simulate_chunked

        entry = "model.qwen2.5-14b.decode.bs64"
        mc = capture_for(entry)
        refs_whole = mc.walk(count_only=True).refs
        header = ("name", "refs", "chunk", "l1_misses", "llc_misses",
                  "lfmr", "mpki")
        rows = []
        for cfg in (cachesim.host_config(4), cachesim.ndp_config(4)):
            sim = simulate_chunked(
                mc.walk_stream(), cfg, chunk=DEFAULT_CHUNK,
                name=f"{entry}.{cfg.name}",
                scan="jax" if args.backend == "jax" else None)
            rows.append((sim.name, refs_whole, DEFAULT_CHUNK,
                         sim.l1_misses, sim.level_misses[-1],
                         round(sim.lfmr, 4), round(sim.mpki, 2)))
        return rows, header

    # megaref: the chunk-streaming path over a single long trace with a
    # bounded footprint (the whole-model shape: refs grow, the working
    # set does not).  Deterministic synthetic stream so the section is
    # comparable across runs; always cold — nothing here touches a store.
    def megaref_rows():
        import numpy as np

        from repro.core import cachesim
        from repro.core.cachesim_stream import DEFAULT_CHUNK, simulate_chunked

        n = 2_000_000 if args.fast else 10_000_000
        rng = np.random.default_rng(0)
        sweep = (np.arange(n, dtype=np.int64) * 3) % (1 << 19)
        hot = rng.integers(0, 4_096, n, dtype=np.int64)
        addr = np.where(rng.random(n) < 0.3, hot, sweep) * 8
        header = ("name", "refs", "chunk", "l1_misses", "llc_misses",
                  "lfmr", "mpki")
        rows = []
        for cfg in (cachesim.host_config(4), cachesim.ndp_config(4)):
            sim = simulate_chunked(addr, cfg, chunk=DEFAULT_CHUNK,
                                   name=f"megaref.{cfg.name}",
                                   scan="jax" if args.backend == "jax"
                                   else None)
            rows.append((sim.name, n, DEFAULT_CHUNK, sim.l1_misses,
                         sim.level_misses[-1], round(sim.lfmr, 4),
                         round(sim.mpki, 2)))
        return rows, header

    sections = {
        "fig1": lambda: paper_figures.fig1_roofline_mpki(study),
        "fig3": lambda: paper_figures.fig3_locality_clustering(study),
        "fig4": lambda: paper_figures.fig4_lfmr_mpki(study),
        "fig5": lambda: paper_figures.fig5_scalability(study),
        "fig5_nuca": lambda: paper_figures.fig5_scalability(study, nuca=True),
        "fig7": lambda: paper_figures.fig7_energy(study),
        "fig18": lambda: paper_figures.fig18_summary_and_validation(study),
        "table3": lambda: paper_figures.table3_suite_roster(suite_runner()),
        "suite": suite_histogram,
        # warm must follow cold in dict order; an --only serving_warm run
        # fills the throwaway store inside its own timing (still a valid
        # upper bound on the recall path)
        "serving": lambda: serving_roster("serving"),
        "serving_warm": lambda: serving_roster("serving_warm"),
        "models": models_roster,
        "models_sweep": models_sweep_rows,
        "megaref": megaref_rows,
        "case1": lambda: paper_figures.case1_noc(study),
        "case2": lambda: paper_figures.case2_accelerators(study),
        "case3": lambda: paper_figures.case3_core_models(study),
        "case4": lambda: paper_figures.case4_offload(study),
        "roofline": roofline_table.rows,
        "kernels_stream": kernel_bench.stream_rows,
        "kernels_attention": kernel_bench.attention_rows,
    }
    if args.fast:
        sections.pop("fig18")  # the 70-workload held-out sweep is slow

    timings: dict[str, dict] = {}
    t_start = time.time()
    for name, fn in sections.items():
        if args.only and args.only != name:
            continue
        t0 = time.time()
        result = fn()
        rows = emit(name, result)
        dt = time.time() - t0
        timings[name] = {"seconds": round(dt, 2), "rows": len(rows)}
        print(f"# {name}: {len(rows)} rows in {dt:.1f}s")

    s = study.stats
    print(f"# engine: {study.engine.cells} cells, "
          f"{s.sim_runs} simulated, {s.sim_hits} cache hits "
          f"({s.sim_hit_rate:.0%} hit rate)")

    if args.bench_json:
        backend = args.backend or default_backend()
        config_key = (f"{'fast' if args.fast else 'full'}"
                      f"-refs{refs}-{backend}")
        payload = {
            "meta": {
                "fast": args.fast,
                "refs": refs,
                "backend": backend,
                "batch": "simulate_batch",  # single-pass engine batching
                "cpus": os.cpu_count(),
                "calibration_seconds": round(calibration_seconds(), 4),
            },
            "sections": timings,
        }
        if not args.only:
            # total wall-clock and engine stats describe a *complete* run;
            # an --only run merges just its own section timings so it
            # cannot misattribute partial-run stats to the whole bucket
            payload["total_seconds"] = round(time.time() - t_start, 2)
            payload["engine"] = s.as_dict()
        write_bench_json(args.bench_json, config_key, payload,
                         partial=bool(args.only))
        print(f"# perf record -> {args.bench_json} [{config_key}]")


if __name__ == "__main__":
    main()
