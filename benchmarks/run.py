"""Benchmark driver: one section per DAMOV table/figure + the TPU tables.

Usage::

    PYTHONPATH=src python -m benchmarks.run [--fast] [--only SECTION]

Sections map 1:1 to paper artifacts:

- fig1   — roofline + MPKI vs NDP speedup (Fig. 1)
- fig3   — locality-based clustering (Fig. 3)
- fig4   — LFMR/MPKI per function (Fig. 4)
- fig5   — scalability curves, 3 systems (Figs. 5, 16)
- fig7   — energy breakdowns (Figs. 7-17)
- fig18  — per-class NDP-speedup summary + §3.5 validation accuracy
- case1..case4 — §5 case studies
- roofline — §Roofline TPU table (from results/dryrun artifacts)
- kernels  — Pallas kernel microbench + v5e roofline bounds
"""

from __future__ import annotations

import argparse
import sys
import time

from . import kernel_bench, paper_figures, roofline_table


def emit(section: str, rows, header) -> None:
    print(f"\n## {section}")
    print(",".join(str(h) for h in header))
    for r in rows:
        print(",".join(str(x) for x in r))
    sys.stdout.flush()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="reduced trace length (CI)")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    refs = 20_000 if args.fast else 60_000
    suite = paper_figures._suite(refs)

    sections = {
        "fig1": lambda: paper_figures.fig1_roofline_mpki(suite),
        "fig3": lambda: paper_figures.fig3_locality_clustering(suite),
        "fig4": lambda: paper_figures.fig4_lfmr_mpki(suite),
        "fig5": lambda: paper_figures.fig5_scalability(suite),
        "fig5_nuca": lambda: paper_figures.fig5_scalability(suite, nuca=True),
        "fig7": lambda: paper_figures.fig7_energy(suite),
        "fig18": paper_figures.fig18_summary_and_validation,
        "case1": lambda: paper_figures.case1_noc(suite),
        "case2": lambda: paper_figures.case2_accelerators(suite),
        "case3": lambda: paper_figures.case3_core_models(suite),
        "case4": lambda: paper_figures.case4_offload(suite),
        "roofline": roofline_table.rows,
        "kernels_stream": kernel_bench.stream_rows,
        "kernels_attention": kernel_bench.attention_rows,
    }
    if args.fast:
        sections.pop("fig18")  # the 70-workload held-out sweep is slow

    for name, fn in sections.items():
        if args.only and args.only != name:
            continue
        t0 = time.time()
        rows, header = fn()
        emit(name, rows, header)
        print(f"# {name}: {len(rows)} rows in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
