"""Kernel micro-benchmarks (CPU wall time of the jnp oracle + interpret
kernel, plus the TPU-roofline bytes/flops each call would move).

On this CPU container the wall times exercise the harness; the derived
column reports the v5e-roofline time so the table is meaningful for the
target hardware (STREAM envelope = HBM roof; attention = MXU roof).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.hlo_analysis import TPU_V5E
from repro.kernels.flash_attention import attention_ref
from repro.kernels.stream import bytes_moved, ref as stream_ref


def _time(fn, *args, iters=3):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters


def stream_rows():
    rows = []
    n = 4 * 2**20  # 4 Mi elems f32 = 16 MB per array
    a = jnp.ones((n,), jnp.float32)
    b = jnp.ones((n,), jnp.float32)
    jitted = {
        "copy": jax.jit(stream_ref.copy_ref),
        "scale": jax.jit(lambda x: stream_ref.scale_ref(x, 3.0)),
        "add": jax.jit(stream_ref.add_ref),
        "triad": jax.jit(lambda x, y: stream_ref.triad_ref(x, y, 3.0)),
    }
    for op, fn in jitted.items():
        args = (a,) if op in ("copy", "scale") else (a, b)
        t = _time(fn, *args)
        nbytes = bytes_moved(op, n, 4)
        roof = nbytes / TPU_V5E.hbm_bw
        rows.append((f"stream_{op}", round(t * 1e6, 1), nbytes,
                     f"{roof*1e6:.1f}us@819GB/s"))
    return rows, ("kernel", "cpu_us_per_call", "bytes",
                  "v5e_hbm_roof_time")


def attention_rows():
    rows = []
    for (b, s, h, g, d) in [(1, 1024, 8, 8, 128), (1, 2048, 8, 2, 128)]:
        q = jnp.ones((b, s, h, d), jnp.bfloat16)
        k = jnp.ones((b, s, g, d), jnp.bfloat16)
        v = jnp.ones((b, s, g, d), jnp.bfloat16)
        fn = jax.jit(lambda q, k, v: attention_ref(q, k, v, causal=True))
        t = _time(fn, q, k, v)
        flops = 4 * b * h * d * s * s / 2
        roof = flops / TPU_V5E.peak_flops
        rows.append((f"attn_b{b}s{s}h{h}g{g}", round(t * 1e6, 1),
                     int(flops), f"{roof*1e6:.1f}us@197TF"))
    return rows, ("kernel", "cpu_us_per_call", "flops", "v5e_mxu_roof_time")
