"""CI perf-regression gate over the ``benchmarks.run`` section record.

``benchmarks.run`` writes a machine-readable perf record (per-section
wall-clock, bucketed per configuration) to ``BENCH.json``; the
repository commits one as the performance baseline.  ``timing_smoke``
gates only single-cell simulation latency, so a regression in the *batch*
paths (engine batching, suite runner, figure queries) used to be
invisible to CI.  This gate closes that hole::

    python -m benchmarks.run --fast --bench-json bench-ci.json
    python -m benchmarks.perf_gate --current bench-ci.json

compares every section's wall-clock in the fresh record against the
committed baseline under the same configuration bucket and exits non-zero
when any section regressed by more than ``--max-ratio`` (default 2.0 —
wide enough to absorb runner variance, tight enough to catch an
accidentally-serialized batch path).  Sections faster than
``--min-seconds`` in the baseline are compared against that floor instead
(timer noise on a 0.0 s section is not a regression signal); sections
present on only one side are reported but never fail the gate (new or
renamed sections should not need a baseline edit in the same commit).
The ``serving_warm`` section — pure content-addressed store recall of the
serving roster — is expected to sit under the noise floor; it is gated by
the ``--min-seconds`` floor rather than its own (near-zero) baseline, so
only a recall path that has become genuinely slow (seconds, not
milliseconds) trips it.

The committed baseline encodes the wall-clock of the machine that
recorded it; to keep the gate meaningful on a runner of different speed,
``benchmarks.run`` also records a fixed NumPy calibration workload's
wall-clock (``meta.calibration_seconds``) and the gate scales the
baseline by the measured speed ratio when both records carry it (capped
to [1/4, 4] so a corrupt calibration cannot neuter the gate).  Sections
that time *real kernel* wall-clock (``kernels_stream`` /
``kernels_attention`` measure achieved GB/s of jitted Pallas kernels)
are jit-noise-bound rather than simulator-bound — CI skips them via
``--skip``.

Structural counter gates (``--obs-trace``)
------------------------------------------
Wall-clock ratios catch a path that got *slow*; they cannot catch a path
that silently lost its sharing structure while staying (barely) inside
the envelope.  With ``--obs-trace TRACE.jsonl`` (a ``repro.obs`` trace,
recorded via ``--trace`` on the suite CLI) the gate additionally asserts
*counter invariants*::

    # cold roster: every profile pass goes through the trace memo —
    # one StreamProfile scan per unique geometry, never more
    python -m benchmarks.perf_gate --obs-trace cold.jsonl \
        --obs-require profile.scan==profile.geom

    # warm rerun: pure store recall — zero cold recalls, zero sims
    python -m benchmarks.perf_gate --obs-trace warm.jsonl \
        --obs-require store.recall.cold==0 \
        --obs-require engine.sim.run==0 --obs-require profile.scan==0

    # the per-stage spans must cover the end-to-end wall-clock
    python -m benchmarks.perf_gate --obs-trace cold.jsonl \
        --obs-min-coverage suite.registry+suite.run=0.9

``--obs-require`` takes ``NAME OP NAME-or-NUMBER`` (operators ``==``
``!=`` ``<=`` ``>=`` ``<`` ``>``; a name resolves to the merged counter
value, missing counters read as 0; ``span:NAME`` resolves to that span's
total seconds).  ``--obs-min-coverage A+B=F`` requires the summed span
totals of ``A``/``B`` to cover at least fraction ``F`` of the trace's
end-to-end wall — the ROADMAP item 3 target ("one profile pass per
unique geometry", "roster bounded by recall") expressed as a regression
gate instead of a hope.  With ``--obs-trace`` given, ``--current`` is
optional, so CI can run the counter gate on a trace alone.
"""

from __future__ import annotations

import argparse
import json
import operator
import sys

DEFAULT_BASELINE = "BENCH.json"
# The perf record lived at BENCH_PR4.json before it became rolling; both
# spellings load (with a stderr note) so older branches/scripts keep
# working.
_BASELINE_ALIASES = {"BENCH.json": "BENCH_PR4.json",
                     "BENCH_PR4.json": "BENCH.json"}
DEFAULT_CONFIG = "fast-refs20000-vectorized"


def load_sections(path: str, config: str) -> dict[str, float]:
    return _load_bucket(path, config)[0]


def _open_record(path: str) -> dict:
    try:
        with open(path) as f:
            return json.load(f)
    except FileNotFoundError:
        alias = _BASELINE_ALIASES.get(path)
        if alias is None:
            raise
        try:
            with open(alias) as f:
                record = json.load(f)
        except FileNotFoundError:
            raise SystemExit(
                f"{path}: not found (nor its former name {alias})")
        print(f"# perf_gate: {path} not found; loaded {alias} "
              f"(renamed baseline)", file=sys.stderr)
        return record


def _load_bucket(path: str, config: str) -> tuple[dict[str, float], float]:
    """(per-section seconds, meta calibration seconds or 0.0)."""
    record = _open_record(path)
    bucket = record.get("runs", {}).get(config)
    if bucket is None:
        raise SystemExit(
            f"{path}: no '{config}' bucket under 'runs' "
            f"(have: {sorted(record.get('runs', {}))})")
    sections = {name: float(entry["seconds"])
                for name, entry in bucket.get("sections", {}).items()}
    cal = float(bucket.get("meta", {}).get("calibration_seconds", 0.0))
    return sections, cal


def speed_factor(base_cal: float, cur_cal: float) -> float:
    """Baseline scaling for machine-speed difference, capped to [1/4, 4].

    > 1 means the current machine is slower than the recording machine,
    so baseline seconds are inflated before comparison.  0/absent
    calibration on either side disables normalization (factor 1.0).
    """
    if base_cal <= 0.0 or cur_cal <= 0.0:
        return 1.0
    return min(4.0, max(0.25, cur_cal / base_cal))


def gate(baseline: dict[str, float], current: dict[str, float], *,
         max_ratio: float, min_seconds: float, factor: float = 1.0,
         out=sys.stdout) -> list[str]:
    """Compare per-section wall-clock; return the failing section names.

    ``factor`` scales the baseline for machine-speed difference (see
    :func:`speed_factor`) before the ratio test.
    """
    failures: list[str] = []
    if factor != 1.0:
        print(f"machine-speed normalization: baseline x {factor:.2f}",
              file=out)
    print(f"{'section':18s} {'base_s':>8s} {'now_s':>8s} {'ratio':>7s}",
          file=out)
    for name in sorted(set(baseline) | set(current)):
        if name not in current:
            print(f"{name:18s} {baseline[name]:8.2f} {'-':>8s} {'-':>7s}  "
                  f"(absent from current run)", file=out)
            continue
        if name not in baseline:
            print(f"{name:18s} {'-':>8s} {current[name]:8.2f} {'-':>7s}  "
                  f"(no baseline; informational)", file=out)
            continue
        floor = max(baseline[name] * factor, min_seconds)
        ratio = current[name] / floor
        verdict = ""
        if current[name] > max_ratio * floor:
            failures.append(name)
            verdict = f"  REGRESSION (> {max_ratio:g}x)"
        print(f"{name:18s} {baseline[name]:8.2f} {current[name]:8.2f} "
              f"{ratio:7.2f}{verdict}", file=out)
    return failures


# --------------------------------------------------------------------------
# Structural counter gates over a repro.obs trace
# --------------------------------------------------------------------------
_OBS_OPS = {
    "==": operator.eq, "!=": operator.ne, "<=": operator.le,
    ">=": operator.ge, "<": operator.lt, ">": operator.gt,
}


def parse_require(expr: str) -> tuple[str, str, str]:
    """``"profile.scan==profile.geom"`` -> ``(lhs, op, rhs)``."""
    for op in ("==", "!=", "<=", ">=", "<", ">"):  # 2-char ops first
        if op in expr:
            lhs, rhs = expr.split(op, 1)
            lhs, rhs = lhs.strip(), rhs.strip()
            if lhs and rhs:
                return lhs, op, rhs
    raise SystemExit(
        f"bad --obs-require {expr!r}; expected NAME OP NAME-or-NUMBER "
        f"with OP in {sorted(_OBS_OPS)}")


def _resolve(rep, token: str) -> float:
    """Numeric literal, ``span:NAME`` total seconds, or counter value."""
    try:
        return float(token)
    except ValueError:
        pass
    if token.startswith("span:"):
        return rep.span_total(token[len("span:"):])
    return rep.counter(token, 0.0)


def obs_gate(rep, requires: list[str], coverages: list[str], *,
             out=sys.stdout) -> list[str]:
    """Check counter invariants + span coverage; return failed checks.

    ``rep`` is a :class:`repro.obs.report.ObsReport`; ``requires`` are
    raw ``--obs-require`` expressions, ``coverages`` raw
    ``--obs-min-coverage`` specs (``NAME[+NAME...]=FRACTION``).
    """
    failures: list[str] = []
    for expr in requires:
        lhs, op, rhs = parse_require(expr)
        lv, rv = _resolve(rep, lhs), _resolve(rep, rhs)
        ok = _OBS_OPS[op](lv, rv)
        verdict = "ok" if ok else "VIOLATED"
        print(f"obs require  {expr:44s} [{lv:g} {op} {rv:g}]  {verdict}",
              file=out)
        if not ok:
            failures.append(expr)
    for spec in coverages:
        names, _, frac_text = spec.partition("=")
        try:
            frac = float(frac_text)
        except ValueError:
            raise SystemExit(
                f"bad --obs-min-coverage {spec!r}; expected "
                f"NAME[+NAME...]=FRACTION")
        total = sum(rep.span_total(n.strip())
                    for n in names.split("+") if n.strip())
        cov = total / rep.wall_s if rep.wall_s else 0.0
        ok = cov >= frac
        verdict = "ok" if ok else "VIOLATED"
        print(f"obs coverage {names:44s} [{total:.3f}s / "
              f"{rep.wall_s:.3f}s = {cov:.1%} >= {frac:.0%}]  {verdict}",
              file=out)
        if not ok:
            failures.append(spec)
    return failures


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m benchmarks.perf_gate",
        description="fail CI when a benchmarks.run section's wall-clock "
                    "regresses vs the committed perf record")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help=f"committed perf record (default {DEFAULT_BASELINE}; "
                         "the former BENCH_PR4.json name still loads)")
    ap.add_argument("--current", default=None,
                    help="perf record written by the CI benchmarks.run "
                         "(required unless --obs-trace alone is gated)")
    ap.add_argument("--config", default=DEFAULT_CONFIG,
                    help=f"runs bucket to compare (default {DEFAULT_CONFIG})")
    ap.add_argument("--max-ratio", type=float, default=2.0,
                    help="fail when current > max-ratio * baseline "
                         "(default 2.0)")
    ap.add_argument("--min-seconds", type=float, default=0.75,
                    help="baseline floor; faster baseline sections are "
                         "compared against this instead (default 0.75)")
    ap.add_argument("--skip", default="", metavar="S[,S]",
                    help="comma list of sections to exclude (e.g. the "
                         "machine-bound kernel wall-clock sections)")
    ap.add_argument("--obs-trace", default=None, metavar="TRACE.jsonl",
                    action="append",
                    help="repro.obs trace file(s) to merge and gate "
                         "counter invariants over (repeatable)")
    ap.add_argument("--obs-require", default=[], action="append",
                    metavar="EXPR",
                    help="counter invariant, e.g. store.recall.cold==0 "
                         "or profile.scan<=profile.geom (repeatable; "
                         "needs --obs-trace)")
    ap.add_argument("--obs-min-coverage", default=[], action="append",
                    metavar="NAME[+NAME..]=FRACTION",
                    help="require the named spans' summed total to cover "
                         "at least FRACTION of the trace wall-clock "
                         "(repeatable; needs --obs-trace)")
    args = ap.parse_args(argv)

    if (args.obs_require or args.obs_min_coverage) and not args.obs_trace:
        ap.error("--obs-require/--obs-min-coverage need --obs-trace")
    if args.current is None and not args.obs_trace:
        ap.error("--current is required (unless gating --obs-trace alone)")

    failures: list[str] = []
    current: dict[str, float] = {}
    if args.current is not None:
        skip = {s.strip() for s in args.skip.split(",") if s.strip()}
        base_sections, base_cal = _load_bucket(args.baseline, args.config)
        cur_sections, cur_cal = _load_bucket(args.current, args.config)
        baseline = {k: v for k, v in base_sections.items() if k not in skip}
        current = {k: v for k, v in cur_sections.items() if k not in skip}
        failures += gate(baseline, current, max_ratio=args.max_ratio,
                         min_seconds=args.min_seconds,
                         factor=speed_factor(base_cal, cur_cal))

    obs_failures: list[str] = []
    if args.obs_trace:
        from repro.obs.report import aggregate

        rep = aggregate(args.obs_trace)
        obs_failures = obs_gate(rep, args.obs_require,
                                args.obs_min_coverage)
        failures += obs_failures

    if failures:
        wall = [f for f in failures if f not in obs_failures]
        parts = []
        if wall:
            parts.append(f"{', '.join(wall)} regressed beyond "
                         f"{args.max_ratio:g}x")
        if obs_failures:
            parts.append(f"counter invariant(s) violated: "
                         f"{'; '.join(obs_failures)}")
        print(f"perf gate FAILED: {'; '.join(parts)}", file=sys.stderr)
        return 1
    checked = []
    if args.current is not None:
        checked.append(f"{len(current)} section(s) within "
                       f"{args.max_ratio:g}x of baseline")
    if args.obs_trace:
        checked.append(f"{len(args.obs_require) + len(args.obs_min_coverage)}"
                       f" counter invariant(s) hold")
    print(f"perf gate OK: {'; '.join(checked)}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
