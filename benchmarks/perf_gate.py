"""CI perf-regression gate over the ``benchmarks.run`` section record.

``benchmarks.run`` writes a machine-readable perf record (per-section
wall-clock, bucketed per configuration) to ``BENCH_PR4.json``; the
repository commits one as the performance baseline.  ``timing_smoke``
gates only single-cell simulation latency, so a regression in the *batch*
paths (engine batching, suite runner, figure queries) used to be
invisible to CI.  This gate closes that hole::

    python -m benchmarks.run --fast --bench-json bench-ci.json
    python -m benchmarks.perf_gate --current bench-ci.json

compares every section's wall-clock in the fresh record against the
committed baseline under the same configuration bucket and exits non-zero
when any section regressed by more than ``--max-ratio`` (default 2.0 —
wide enough to absorb runner variance, tight enough to catch an
accidentally-serialized batch path).  Sections faster than
``--min-seconds`` in the baseline are compared against that floor instead
(timer noise on a 0.0 s section is not a regression signal); sections
present on only one side are reported but never fail the gate (new or
renamed sections should not need a baseline edit in the same commit).
The ``serving_warm`` section — pure content-addressed store recall of the
serving roster — is expected to sit under the noise floor; it is gated by
the ``--min-seconds`` floor rather than its own (near-zero) baseline, so
only a recall path that has become genuinely slow (seconds, not
milliseconds) trips it.

The committed baseline encodes the wall-clock of the machine that
recorded it; to keep the gate meaningful on a runner of different speed,
``benchmarks.run`` also records a fixed NumPy calibration workload's
wall-clock (``meta.calibration_seconds``) and the gate scales the
baseline by the measured speed ratio when both records carry it (capped
to [1/4, 4] so a corrupt calibration cannot neuter the gate).  Sections
that time *real kernel* wall-clock (``kernels_stream`` /
``kernels_attention`` measure achieved GB/s of jitted Pallas kernels)
are jit-noise-bound rather than simulator-bound — CI skips them via
``--skip``.
"""

from __future__ import annotations

import argparse
import json
import sys

DEFAULT_BASELINE = "BENCH_PR4.json"
DEFAULT_CONFIG = "fast-refs20000-vectorized"


def load_sections(path: str, config: str) -> dict[str, float]:
    return _load_bucket(path, config)[0]


def _load_bucket(path: str, config: str) -> tuple[dict[str, float], float]:
    """(per-section seconds, meta calibration seconds or 0.0)."""
    with open(path) as f:
        record = json.load(f)
    bucket = record.get("runs", {}).get(config)
    if bucket is None:
        raise SystemExit(
            f"{path}: no '{config}' bucket under 'runs' "
            f"(have: {sorted(record.get('runs', {}))})")
    sections = {name: float(entry["seconds"])
                for name, entry in bucket.get("sections", {}).items()}
    cal = float(bucket.get("meta", {}).get("calibration_seconds", 0.0))
    return sections, cal


def speed_factor(base_cal: float, cur_cal: float) -> float:
    """Baseline scaling for machine-speed difference, capped to [1/4, 4].

    > 1 means the current machine is slower than the recording machine,
    so baseline seconds are inflated before comparison.  0/absent
    calibration on either side disables normalization (factor 1.0).
    """
    if base_cal <= 0.0 or cur_cal <= 0.0:
        return 1.0
    return min(4.0, max(0.25, cur_cal / base_cal))


def gate(baseline: dict[str, float], current: dict[str, float], *,
         max_ratio: float, min_seconds: float, factor: float = 1.0,
         out=sys.stdout) -> list[str]:
    """Compare per-section wall-clock; return the failing section names.

    ``factor`` scales the baseline for machine-speed difference (see
    :func:`speed_factor`) before the ratio test.
    """
    failures: list[str] = []
    if factor != 1.0:
        print(f"machine-speed normalization: baseline x {factor:.2f}",
              file=out)
    print(f"{'section':18s} {'base_s':>8s} {'now_s':>8s} {'ratio':>7s}",
          file=out)
    for name in sorted(set(baseline) | set(current)):
        if name not in current:
            print(f"{name:18s} {baseline[name]:8.2f} {'-':>8s} {'-':>7s}  "
                  f"(absent from current run)", file=out)
            continue
        if name not in baseline:
            print(f"{name:18s} {'-':>8s} {current[name]:8.2f} {'-':>7s}  "
                  f"(no baseline; informational)", file=out)
            continue
        floor = max(baseline[name] * factor, min_seconds)
        ratio = current[name] / floor
        verdict = ""
        if current[name] > max_ratio * floor:
            failures.append(name)
            verdict = f"  REGRESSION (> {max_ratio:g}x)"
        print(f"{name:18s} {baseline[name]:8.2f} {current[name]:8.2f} "
              f"{ratio:7.2f}{verdict}", file=out)
    return failures


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m benchmarks.perf_gate",
        description="fail CI when a benchmarks.run section's wall-clock "
                    "regresses vs the committed perf record")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help=f"committed perf record (default {DEFAULT_BASELINE})")
    ap.add_argument("--current", required=True,
                    help="perf record written by the CI benchmarks.run")
    ap.add_argument("--config", default=DEFAULT_CONFIG,
                    help=f"runs bucket to compare (default {DEFAULT_CONFIG})")
    ap.add_argument("--max-ratio", type=float, default=2.0,
                    help="fail when current > max-ratio * baseline "
                         "(default 2.0)")
    ap.add_argument("--min-seconds", type=float, default=0.75,
                    help="baseline floor; faster baseline sections are "
                         "compared against this instead (default 0.75)")
    ap.add_argument("--skip", default="", metavar="S[,S]",
                    help="comma list of sections to exclude (e.g. the "
                         "machine-bound kernel wall-clock sections)")
    args = ap.parse_args(argv)

    skip = {s.strip() for s in args.skip.split(",") if s.strip()}
    base_sections, base_cal = _load_bucket(args.baseline, args.config)
    cur_sections, cur_cal = _load_bucket(args.current, args.config)
    baseline = {k: v for k, v in base_sections.items() if k not in skip}
    current = {k: v for k, v in cur_sections.items() if k not in skip}
    failures = gate(baseline, current, max_ratio=args.max_ratio,
                    min_seconds=args.min_seconds,
                    factor=speed_factor(base_cal, cur_cal))
    if failures:
        print(f"perf gate FAILED: {', '.join(failures)} regressed "
              f"beyond {args.max_ratio:g}x", file=sys.stderr)
        return 1
    print(f"perf gate OK: {len(current)} section(s) within "
          f"{args.max_ratio:g}x of baseline", file=sys.stderr)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
