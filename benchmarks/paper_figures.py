"""Benchmarks reproducing every DAMOV table/figure from the simulator
substrate.  Each function returns (rows, header) and prints CSV."""

from __future__ import annotations

import numpy as np

from repro.core import (casestudies, classify, locality, scalability,
                        tracegen)

CORES = scalability.CORE_SWEEP


def _suite(refs=60_000):
    return tracegen.make_suite(refs=refs)


# --------------------------------------------------------------------------
# Figure 1: roofline scatter + MPKI vs NDP speedup
# --------------------------------------------------------------------------
def fig1_roofline_mpki(suite=None):
    suite = suite or _suite()
    rows = []
    for w in suite:
        m = classify.measure(w)
        r = scalability.analyze(w)
        sp = r.speedup_ndp_vs_host()
        # roofline coordinates: AI (flops/byte) vs attained perf fraction
        ai_flops_per_byte = w.ai_ops_per_access / 64.0 * 8
        cat = ("faster_on_ndp" if min(sp) > 1.05 else
               "faster_on_cpu" if max(sp) < 0.95 else
               "similar" if max(sp) < 1.05 and min(sp) > 0.95 else
               "depends")
        rows.append((w.name, w.expected_class, round(ai_flops_per_byte, 3),
                     round(m.mpki, 2), round(float(np.mean(sp)), 3),
                     round(min(sp), 3), round(max(sp), 3), cat))
    return rows, ("name", "class", "ai", "mpki", "ndp_speedup_mean",
                  "min", "max", "fig1_category")


# --------------------------------------------------------------------------
# Figure 3: locality-based clustering (Step 2)
# --------------------------------------------------------------------------
def fig3_locality_clustering(suite=None):
    suite = suite or _suite()
    pts = []
    for w in suite:
        spec = w.trace(1)
        s = locality.spatial_locality(spec.addresses)
        t = locality.temporal_locality(spec.addresses)
        pts.append((w.name, w.expected_class, s, t))
    # k-means, k=2 on temporal locality (the paper's emergent split)
    temps = np.array([p[3] for p in pts])
    c0, c1 = temps.min(), temps.max()
    for _ in range(20):
        assign = np.abs(temps - c0) <= np.abs(temps - c1)
        if assign.any() and (~assign).any():
            c0, c1 = temps[assign].mean(), temps[~assign].mean()
    rows = [(n, c, round(s, 3), round(t, 3),
             "low_temporal" if a else "high_temporal")
            for (n, c, s, t), a in zip(pts, assign)]
    return rows, ("name", "class", "spatial", "temporal", "kmeans_cluster")


# --------------------------------------------------------------------------
# Figure 4: LFMR + MPKI per function
# --------------------------------------------------------------------------
def fig4_lfmr_mpki(suite=None):
    suite = suite or _suite()
    rows = []
    for w in suite:
        m = classify.measure(w)
        rows.append((w.name, w.expected_class, round(m.mpki, 2))
                    + tuple(round(x, 3) for x in m.lfmr_by_cores))
    return rows, ("name", "class", "mpki") + tuple(
        f"lfmr@{c}" for c in CORES)


# --------------------------------------------------------------------------
# Figure 5 (+16): performance scalability curves, 3 systems
# --------------------------------------------------------------------------
def fig5_scalability(suite=None, *, nuca=False):
    suite = suite or _suite()
    rows = []
    for w in suite:
        r = scalability.analyze(w, nuca=nuca)
        for cfg in ("host", "host+pf", "ndp"):
            perf = r.perf_normalized(cfg)
            rows.append((w.name, w.expected_class, cfg)
                        + tuple(round(p, 2) for p in perf))
    return rows, ("name", "class", "system") + tuple(
        f"perf@{c}" for c in CORES)


# --------------------------------------------------------------------------
# Figures 7/9/10/12/14/15 (+17): energy breakdowns
# --------------------------------------------------------------------------
def fig7_energy(suite=None, *, nuca=False):
    suite = suite or _suite()
    rows = []
    for w in suite:
        r = scalability.analyze(w, nuca=nuca)
        for cfg in ("host", "ndp"):
            for p in r.points[cfg]:
                e = p.energy
                rows.append((w.name, w.expected_class, cfg, p.cores,
                             round(e.l1_j * 1e3, 4), round(e.l2_j * 1e3, 4),
                             round(e.l3_j * 1e3, 4), round(e.dram_j * 1e3, 4),
                             round(e.link_j * 1e3, 4),
                             round(e.total_j * 1e3, 4)))
    return rows, ("name", "class", "system", "cores", "l1_mJ", "l2_mJ",
                  "l3_mJ", "dram_mJ", "link_mJ", "total_mJ")


# --------------------------------------------------------------------------
# Figure 18 + §3.5: per-class summary and held-out validation accuracy
# --------------------------------------------------------------------------
def fig18_summary_and_validation():
    train = _suite()
    train_m = [classify.measure(w) for w in train]
    thresholds = classify.derive_thresholds(train_m)

    held = tracegen.make_suite(variants=5, seed=123)[len(train):]
    held_m = [classify.measure(w) for w in held]
    acc, _ = classify.validate(held_m, thresholds)

    rows = []
    for core_model in ("ooo", "inorder"):
        by_class: dict[str, list[float]] = {}
        for w in train:
            r = scalability.analyze(w, core_model=core_model)
            by_class.setdefault(w.expected_class, []).extend(
                r.speedup_ndp_vs_host())
        for cls in sorted(by_class):
            v = np.array(by_class[cls])
            rows.append((core_model, cls, round(float(v.mean()), 3),
                         round(float(v.min()), 3), round(float(v.max()), 3)))
    rows.append(("validation_accuracy", f"{acc:.3f}",
                 f"thresholds: T={thresholds.temporal:.2f} "
                 f"LFMR={thresholds.lfmr:.2f} MPKI={thresholds.mpki:.1f} "
                 f"AI={thresholds.ai:.1f}", "", ""))
    return rows, ("core_model", "class", "ndp_speedup_mean", "min", "max")


# --------------------------------------------------------------------------
# §5 case studies
# --------------------------------------------------------------------------
def case1_noc(suite=None):
    suite = suite or _suite()
    rows = []
    for w in suite[:8]:
        r = casestudies.noc_study(w)
        rows.append((w.name, round(r.mean_hops, 2),
                     round(r.local_fraction, 3), round(r.overhead_pct, 1)))
    return rows, ("name", "mean_hops", "local_fraction", "noc_overhead_pct")


def case2_accelerators(suite=None):
    suite = suite or _suite()
    by = {w.name: w for w in suite}
    rows = []
    for name in ("STRCpy", "LIGPrkEmd", "CHAHsti", "PLYalu", "HPGSpm",
                 "RODNw"):
        w = by[name]
        rows.append((name, w.expected_class,
                     round(casestudies.accelerator_study(w), 3)))
    return rows, ("name", "class", "ndp_accel_speedup_vs_cc_accel")


def case3_core_models(suite=None):
    suite = suite or _suite()
    by = {w.name: w for w in suite}
    rows = []
    for name in ("STRCpy", "LIGPrkEmd", "CHAHsti", "PLYalu", "PLYgemver",
                 "SPLLucb"):
        r = casestudies.core_model_study(by[name])
        rows.append((name, round(r["ndp_inorder_128"], 2),
                     round(r["ndp_ooo_6"], 2)))
    return rows, ("name", "ndp_128_inorder_speedup", "ndp_6_ooo_speedup")


def case4_offload(suite=None):
    suite = suite or _suite()
    by = {w.name: w for w in suite}
    rows = []
    for name in ("LIGPrkEmd", "HSJNPO", "DRKRes"):
        r = casestudies.finegrained_offload_study(by[name])
        rows.append((name, round(r["hottest_block_miss_share"], 3),
                     round(r["speedup_hottest_block"], 3),
                     round(r["speedup_full_function"], 3)))
    return rows, ("name", "hottest_bb_miss_share", "speedup_bb",
                  "speedup_full")
