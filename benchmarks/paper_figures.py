"""Benchmarks reproducing every DAMOV table/figure as queries over one
shared :class:`repro.study.Study`.

Each figure function takes a Study and returns a columnar
:class:`repro.study.StudyResult`.  All figures read from the study's
memoized engine, so the whole set runs one simulation pass: a cell
simulated for Fig. 1 is recalled — not re-simulated — by Figs. 4, 5, 7 and
the case studies.
"""

from __future__ import annotations

import numpy as np

from repro.core import casestudies, classify, tracegen
from repro.study import Study, StudyResult


def default_study(refs: int | None = None, *, backend: str | None = None) -> Study:
    """The standard synthetic-suite study all sections share.

    ``refs`` defaults to :data:`repro.core.tracegen.DEFAULT_REFS`;
    ``backend`` picks the cache-simulation implementation.
    """
    return Study(refs=refs, backend=backend)


def _as_study(study) -> Study:
    if study is None:
        return default_study()
    if isinstance(study, Study):
        return study
    return Study(suite=study)  # a bare workload list


# --------------------------------------------------------------------------
# Figure 1: roofline scatter + MPKI vs NDP speedup
# --------------------------------------------------------------------------
def fig1_roofline_mpki(study=None) -> StudyResult:
    study = _as_study(study)
    res = StudyResult("fig1", ("name", "class", "ai", "mpki",
                               "ndp_speedup_mean", "min", "max",
                               "fig1_category"))
    for w in study:
        m = study.metrics(w)
        r = study.scalability(w)
        sp = r.speedup_ndp_vs_host()
        # roofline coordinates: AI (flops/byte) vs attained perf fraction
        ai_flops_per_byte = w.ai_ops_per_access / 64.0 * 8
        cat = ("faster_on_ndp" if min(sp) > 1.05 else
               "faster_on_cpu" if max(sp) < 0.95 else
               "similar" if max(sp) < 1.05 and min(sp) > 0.95 else
               "depends")
        res.append((w.name, w.expected_class, round(ai_flops_per_byte, 3),
                    round(m.mpki, 2), round(float(np.mean(sp)), 3),
                    round(min(sp), 3), round(max(sp), 3), cat))
    return res


# --------------------------------------------------------------------------
# Figure 3: locality-based clustering (Step 2)
# --------------------------------------------------------------------------
def fig3_locality_clustering(study=None) -> StudyResult:
    study = _as_study(study)
    pts = [(w.name, w.expected_class) + study.locality(w) for w in study]
    # k-means, k=2 on temporal locality (the paper's emergent split)
    temps = np.array([p[3] for p in pts])
    c0, c1 = temps.min(), temps.max()
    for _ in range(20):
        assign = np.abs(temps - c0) <= np.abs(temps - c1)
        if assign.any() and (~assign).any():
            c0, c1 = temps[assign].mean(), temps[~assign].mean()
    res = StudyResult("fig3", ("name", "class", "spatial", "temporal",
                               "kmeans_cluster"))
    for (n, c, s, t), a in zip(pts, assign):
        res.append((n, c, round(s, 3), round(t, 3),
                    "low_temporal" if a else "high_temporal"))
    return res


# --------------------------------------------------------------------------
# Figure 4: LFMR + MPKI per function
# --------------------------------------------------------------------------
def fig4_lfmr_mpki(study=None) -> StudyResult:
    study = _as_study(study)
    res = StudyResult("fig4", ("name", "class", "mpki") + tuple(
        f"lfmr@{c}" for c in study.cores))
    for w in study:
        m = study.metrics(w)
        res.append((w.name, w.expected_class, round(m.mpki, 2))
                   + tuple(round(x, 3) for x in m.lfmr_by_cores))
    return res


# --------------------------------------------------------------------------
# Figure 5 (+16): performance scalability curves, 3 systems
# --------------------------------------------------------------------------
def fig5_scalability(study=None, *, nuca=False) -> StudyResult:
    study = _as_study(study)
    res = StudyResult("fig5_nuca" if nuca else "fig5",
                      ("name", "class", "system") + tuple(
                          f"perf@{c}" for c in study.cores))
    for w in study:
        r = study.scalability(w, nuca=nuca)
        for cfg in ("host", "host+pf", "ndp"):
            res.append((w.name, w.expected_class, cfg) + tuple(
                round(p, 2) for p in r.perf_normalized(cfg)))
    return res


# --------------------------------------------------------------------------
# Figures 7/9/10/12/14/15 (+17): energy breakdowns
# --------------------------------------------------------------------------
def fig7_energy(study=None, *, nuca=False) -> StudyResult:
    study = _as_study(study)
    res = study.energy_table(nuca=nuca)
    res.name = "fig7"
    return res


# --------------------------------------------------------------------------
# Table 3: the registered benchmark-suite roster (classification section).
# Synthetic family expansions and captured Pallas-kernel traces appear in
# one table, classified by one methodology (repro.suite).
# --------------------------------------------------------------------------
def table3_suite_roster(runner=None, *, refs: int | None = None,
                        store=None, backend: str | None = None) -> StudyResult:
    """One row per suite entry: domain, source, metrics, assigned vs
    expected class.  ``runner``: a :class:`repro.suite.SuiteRunner` to
    reuse (engine + result store); otherwise a runner over the default
    registry at ``refs`` is built, persisting to ``store`` (a
    :class:`repro.suite.ResultStore`; None disables persistence) and
    simulating on ``backend``."""
    if runner is None:
        from repro.suite import SuiteRunner, default_registry
        runner = SuiteRunner(default_registry(refs=refs), store=store,
                             backend=backend)
    res = runner.roster()
    res.name = "table3"
    return res


# --------------------------------------------------------------------------
# Figure 18 + §3.5: per-class summary and held-out validation accuracy
# --------------------------------------------------------------------------
def fig18_summary_and_validation(study=None) -> StudyResult:
    study = _as_study(study)
    thresholds = classify.derive_thresholds(study.metrics_all())

    # held-out traces at the same length as the training study's, so
    # thresholds and validation metrics are measured consistently
    held = tracegen.make_suite(refs=study.refs or tracegen.DEFAULT_REFS,
                               variants=5, seed=123)[len(study):]
    held_study = Study(suite=held, backend=study.engine.backend)
    acc, _ = classify.validate(held_study.metrics_all(), thresholds)

    res = StudyResult("fig18", ("core_model", "class", "ndp_speedup_mean",
                                "min", "max"))
    for core_model in ("ooo", "inorder"):
        by_class: dict[str, list[float]] = {}
        for w in study:
            r = study.scalability(w, core_model=core_model)
            by_class.setdefault(w.expected_class, []).extend(
                r.speedup_ndp_vs_host())
        for cls in sorted(by_class):
            v = np.array(by_class[cls])
            res.append((core_model, cls, round(float(v.mean()), 3),
                        round(float(v.min()), 3), round(float(v.max()), 3)))
    res.append(("validation_accuracy", f"{acc:.3f}",
                f"thresholds: T={thresholds.temporal:.2f} "
                f"LFMR={thresholds.lfmr:.2f} MPKI={thresholds.mpki:.1f} "
                f"AI={thresholds.ai:.1f}", "", ""))
    return res


# --------------------------------------------------------------------------
# §5 case studies (shared engine: the 4-core cells are already simulated)
# --------------------------------------------------------------------------
def case1_noc(study=None) -> StudyResult:
    study = _as_study(study)
    res = StudyResult("case1", ("name", "mean_hops", "local_fraction",
                                "noc_overhead_pct"))
    for w in study.suite[:8]:
        r = casestudies.noc_study(w, engine=study.engine)
        res.append((w.name, round(r.mean_hops, 2),
                    round(r.local_fraction, 3), round(r.overhead_pct, 1)))
    return res


def case2_accelerators(study=None) -> StudyResult:
    study = _as_study(study)
    res = StudyResult("case2", ("name", "class",
                                "ndp_accel_speedup_vs_cc_accel"))
    for name in ("STRCpy", "LIGPrkEmd", "CHAHsti", "PLYalu", "HPGSpm",
                 "RODNw"):
        w = study.workload(name)
        res.append((name, w.expected_class,
                    round(casestudies.accelerator_study(
                        w, engine=study.engine), 3)))
    return res


def case3_core_models(study=None) -> StudyResult:
    study = _as_study(study)
    res = StudyResult("case3", ("name", "ndp_128_inorder_speedup",
                                "ndp_6_ooo_speedup"))
    for name in ("STRCpy", "LIGPrkEmd", "CHAHsti", "PLYalu", "PLYgemver",
                 "SPLLucb"):
        r = casestudies.core_model_study(study.workload(name),
                                         engine=study.engine)
        res.append((name, round(r["ndp_inorder_128"], 2),
                    round(r["ndp_ooo_6"], 2)))
    return res


def case4_offload(study=None) -> StudyResult:
    study = _as_study(study)
    res = StudyResult("case4", ("name", "hottest_bb_miss_share",
                                "speedup_bb", "speedup_full"))
    for name in ("LIGPrkEmd", "HSJNPO", "DRKRes"):
        r = casestudies.finegrained_offload_study(study.workload(name),
                                                  engine=study.engine)
        res.append((name, round(r["hottest_block_miss_share"], 3),
                    round(r["speedup_hottest_block"], 3),
                    round(r["speedup_full_function"], 3)))
    return res
