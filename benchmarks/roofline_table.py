"""§Roofline: the per-(arch x shape x mesh) three-term table.

Reads the dry-run artifacts (results/dryrun/*.json).  Falls back to
computing the analytic terms directly (no compile) when a cell artifact is
missing, so `python -m benchmarks.run` works even without the 512-device
dry-run having been executed in this checkout.
"""

from __future__ import annotations

import glob
import json
import os

from repro import configs
from repro.core import analytic, hlo_analysis
from repro.launch.cells import all_cells

RESULTS_DIR = os.environ.get("DRYRUN_DIR", "results/dryrun")

HEADER = ("arch", "shape", "mesh", "t_compute_s", "t_memory_s",
          "t_collective_s", "dominant", "class", "mfu_bound",
          "useful_ratio", "roofline_fraction")


def _from_artifacts() -> dict[tuple, dict]:
    out = {}
    for f in glob.glob(os.path.join(RESULTS_DIR, "*.json")):
        if "_skips" in f:
            continue
        d = json.load(open(f))
        if d.get("status") == "ok":
            out[(d["arch"], d["shape"], d["mesh"])] = d
    return out


def _analytic_entry(plan, mesh_name: str) -> dict:
    chips = 512 if mesh_name == "2x16x16" else 256
    model_shards = 16
    data_shards = chips // model_shards
    c = analytic.cell_cost(plan.cfg, plan.shape, kind=plan.kind,
                           microbatches=plan.microbatches,
                           data_shards=data_shards,
                           model_shards=model_shards,
                           infer_fsdp=plan.infer_fsdp)
    tokens = plan.shape.global_batch * (
        plan.shape.seq_len if plan.kind != "decode" else 1)
    rt = hlo_analysis.RooflineTerms(
        name=f"{plan.name}@{mesh_name}", chips=chips,
        hlo_flops=c.flops, hlo_bytes=c.hbm_bytes,
        collective_bytes=c.collective_bytes,
        model_flops=plan.cfg.model_flops(tokens,
                                         training=plan.kind == "train"))
    return {"arch": plan.arch, "shape": plan.shape.name, "mesh": mesh_name,
            **rt.summary()}


def rows():
    arts = _from_artifacts()
    out = []
    for plan in all_cells():
        for mesh_name in ("16x16", "2x16x16"):
            d = arts.get((plan.arch, plan.shape.name, mesh_name))
            if d is None:
                d = _analytic_entry(plan, mesh_name)
            out.append((d["arch"], d["shape"], d["mesh"],
                        f"{d['t_compute_s']:.3e}", f"{d['t_memory_s']:.3e}",
                        f"{d['t_collective_s']:.3e}", d["dominant"],
                        d["class"], round(d["mfu_bound"], 3),
                        round(d.get("useful_compute_ratio", 0.0), 3),
                        round(d.get("roofline_fraction", 0.0), 3)))
    # assignment-mandated skips, for table completeness
    for arch in configs.ARCHS:
        if "long_500k" not in configs.shapes_for(arch):
            out.append((arch, "long_500k", "-", "-", "-", "-", "-",
                        "skipped (full attention)", "-", "-", "-"))
    return out, HEADER
